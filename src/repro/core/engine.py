"""PlasticEngine: the backend-dispatched fused layer step (product hot path).

One `layer_step` = one SNN timestep for ONE synaptic layer, running the
Forward Engine (psum matmul -> neuron dynamics -> trace update) and the
Plasticity Engine (four-term dw, weights rewritten in place) as a single
fused program — the FireFly-P dual-engine overlap (Secs. III-B/C).

Every consumer of the rule — `core/snn.timestep`, the adaptation loops, the
LM plastic adapter, serving, examples, and benchmarks — routes layer steps
through this module, so the Pallas kernel is the single source of truth for
the hot path rather than a benchmark artifact.

Backends (`impl`):

  * ``"xla"``              — pure-jnp oracle (kernels/plasticity/ref).  What
                             CPU runs and dry-runs lower; bit-stable with the
                             historical hand-rolled jnp layer loop.
  * ``"pallas"``           — the fused Pallas TPU kernel
                             (kernels/plasticity/kernel).
  * ``"pallas-interpret"`` — same kernel body executed by the Pallas
                             interpreter; validates the TPU program on CPU.

`layer_step` accepts unbatched ``(N,)`` or batched ``(B, N)`` state.  Two
batched semantics, selected by the weight rank:

  * SHARED weights ``w (N, M)`` with batched activations — the dw is
    batch-averaged (delta_w semantics; e.g. batched MNIST online learning).
  * FLEET mode, ``w (B, N, M)`` — every request stream owns and rewrites
    its OWN synapses with a per-sample dw under one shared rule theta.
    All three backends run the whole fleet as ONE fused program (the Pallas
    kernel launches a ``(cdiv(M, bm), B)`` grid, streams innermost so the
    shared theta tile is fetched once per tile); this replaces the old
    recipe of `jax.vmap`-ing `layer_step` per stream, which broadcast the
    shared rule theta B-fold and never lowered through `pallas_call` at
    all (the batching rule rejects unmapped operands).

Fleet mode additionally accepts an ``active (B,)`` slot mask (the session-
serving contract, `repro.serving`): streams whose flag is false are frozen
bit-exactly — weights, membrane, and traces unchanged, events zero — so a
fixed-shape slot pool under continuous batching never drifts in its vacant
slots and occupancy changes never recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import plasticity as _P
from repro.kernels.plasticity import fused as _fused
from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import quant as _Q
from repro.kernels.plasticity import ref as _ref
from repro.kernels.plasticity.quant import QuantConfig
from repro.obs.telemetry import (FleetTelemetry, sat_threshold,
                                 sat_threshold_q)

IMPLS = ("xla", "pallas", "pallas-interpret")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerState:
    """State slice the dual-engine step reads and rewrites for one layer.

    ``trace_pre`` is the *already-updated* presynaptic trace for the current
    timestep (the predecessor layer's Trace Update Unit runs upstream);
    ``trace_post`` is the previous timestep's postsynaptic trace, which
    `layer_step` advances and returns.  ``theta`` is the packed
    ``(4, n_pre, n_post)`` rule; ``None`` for non-plastic layers.

    A leading batch rank on ``w`` (``(B, N, M)``) puts the layer in FLEET
    mode: per-request weights, per-sample dw (see `layer_step`).
    """

    w: jax.Array                        # (N, M) | (B, N, M) synaptic weights
    v: jax.Array                        # (M,) | (B, M) membrane potential
    trace_pre: jax.Array                # (N,) | (B, N)
    trace_post: jax.Array               # (M,) | (B, M)
    theta: Optional[jax.Array] = None   # (4, N, M) packed rule coefficients
    w_scale: Optional[jax.Array] = None  # () | (B,) int8 weight scale (quant)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetworkState:
    """Whole-network state: per-layer weights/membranes, per-population traces.

    Replaces the historical raw ``{"w": [...], "v": [...], "trace": [...]}``
    dict; registered as a pytree so it threads through jit/scan/vmap.
    ``trace`` has ``num_layers + 1`` entries — ``trace[i]`` is layer i's
    presynaptic population (``trace[0]`` is the input drive's trace).
    """

    w: Tuple[jax.Array, ...]
    v: Tuple[jax.Array, ...]
    trace: Tuple[jax.Array, ...]
    t: jax.Array
    # Fixed-point mode only: per-layer int8 weight scales (() shared /
    # (B,) fleet — one scale per slot).  Empty tuple in float mode, so the
    # pytree stays leaf-compatible with pre-quant states and checkpoints.
    w_scale: Tuple[jax.Array, ...] = ()

    @property
    def num_layers(self) -> int:
        return len(self.w)

    def layer(self, i: int, theta=None) -> LayerState:
        """View layer i as a LayerState (traces must be current-timestep)."""
        return LayerState(w=self.w[i], v=self.v[i], trace_pre=self.trace[i],
                          trace_post=self.trace[i + 1], theta=theta,
                          w_scale=self.w_scale[i] if self.w_scale else None)


@dataclasses.dataclass(frozen=True)
class EngineParams:
    """Static per-layer parameters of the fused step (hashable; jit-static)."""

    tau_m: float = 2.0
    v_th: float = 1.0
    v_reset: float = 0.0
    trace_decay: float = 0.8
    w_clip: float = 4.0
    plastic: bool = True
    spiking: bool = True        # False => leaky readout (event = tanh(V))
    block_m: int = 128          # Pallas postsynaptic tile width
    quant: Optional[QuantConfig] = None  # fixed-point mode (None = float32)


def _occupancy(active, b) -> jax.Array:
    if active is None:
        return jnp.ones((b,), jnp.float32)
    return active.reshape(-1).astype(jnp.float32)


def layer_step(state: LayerState, x: jax.Array, *,
               params: EngineParams = EngineParams(),
               impl: str = "xla",
               teach: Optional[jax.Array] = None,
               active: Optional[jax.Array] = None,
               seed: Optional[jax.Array] = None,
               telemetry: bool = False
               ) -> tuple[LayerState, jax.Array]:
    """One fused forward+plasticity step for one layer.

    Args:
      state: layer state; rewritten functionally (w, v, trace_post advance).
             ``state.w`` of rank 3 (``(B, N, M)``) selects FLEET mode: one
             fused launch steps B per-request weight sets with per-sample dw.
      x:     presynaptic events ``(N,)`` or ``(B, N)``.
      params: static engine parameters.
      impl:  ``"xla"`` | ``"pallas"`` | ``"pallas-interpret"``.
      teach: optional teaching current added to the psum ``(M,)``/``(B, M)``
             (supervised online learning on the output layer).  In fleet
             mode an unbatched ``(M,)`` teach broadcasts to every stream.
      active: optional fleet-only ``(B,)`` slot mask (bool or 0/1).  Streams
             with a false flag are TRUE no-ops: weights, membrane, and
             traces come back bit-identical and their events are zero.
             This is the contract the session-serving scheduler uses to run
             a partially occupied fixed-shape slot pool without recompiling
             or letting vacant slots drift.
      seed:  fixed-point mode only — the step counter driving the
             deterministic stochastic round of dw (scalar; fleet mode takes
             a ``(B,)`` vector of per-SESSION counters so a session's
             update stream is invariant to its slot).  Defaults to 0.
      telemetry: fleet-only STATIC flag — the backends emit one extra
             reduced output (per-slot raw sums) inside the same fused
             program, returned here normalized as an `obs.FleetTelemetry`
             third result.  Because the flag is static, telemetry-off
             traces are byte-identical to the uninstrumented program and
             telemetry-on is exactly one additional stable executable per
             entry point (never per-step churn).

    Returns:
      ``(new_state, out)`` — ``out`` is the layer's output events: spikes for
      spiking layers, the membrane potential for the leaky readout.  With
      ``telemetry=True``: ``(new_state, out, FleetTelemetry)`` (vacant
      slots report zeros in every telemetry field).
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    plastic = params.plastic and state.theta is not None
    qc = params.quant
    if qc is not None:
        # Loud contracts: the fixed-point datapath implements power-of-two
        # dynamics; a float EngineParams that silently disagrees would make
        # "float vs quant" comparisons measure the wrong thing.
        if params.tau_m != qc.tau_m:
            raise ValueError(
                f"quant mode implements tau_m = 2**tau_shift = {qc.tau_m}; "
                f"set EngineParams.tau_m to match (got {params.tau_m})")
        if abs(params.trace_decay - qc.decay) > 1e-9:
            raise ValueError(
                f"quant mode implements trace_decay = 1 - 2**-trace_shift "
                f"= {qc.decay}; set EngineParams.trace_decay to match "
                f"(got {params.trace_decay})")
        checks = [("w", state.w, jnp.int8), ("x", x, jnp.int32),
                  ("v", state.v, jnp.int32),
                  ("trace_pre", state.trace_pre, jnp.int32),
                  ("trace_post", state.trace_post, jnp.int32)]
        if teach is not None:
            # a float teach would be silently truncated toward zero by the
            # fixed-point cast (|teach| < 1 -> exactly 0); demand the same
            # int32 event-bus format as every other operand
            checks.append(("teach", teach, jnp.int32))
        for name, arr, want in checks:
            if arr.dtype != want:
                raise ValueError(
                    f"quant mode needs {name} of dtype {jnp.dtype(want).name} "
                    f"(build state with snn.init_state on a quant config or "
                    f"snn.quantize_state; quantize drive/teach with "
                    f"kernels.plasticity.quant.to_fixed); got {arr.dtype}")
        kw = dict(qcfg=qc, v_th=params.v_th, v_reset=params.v_reset,
                  w_clip=params.w_clip, plastic=plastic,
                  spiking=params.spiking, seed=seed)
    else:
        kw = dict(tau_m=params.tau_m, v_th=params.v_th,
                  v_reset=params.v_reset, trace_decay=params.trace_decay,
                  w_clip=params.w_clip, plastic=plastic,
                  spiking=params.spiking)

    fleet = state.w.ndim == 3                   # fleet: per-request weights
    if fleet:
        b, n, m = state.w.shape
        if x.ndim != 2 or x.shape[0] != b:
            raise ValueError(
                f"fleet mode needs x of shape (B, N) matching w (B, N, M); "
                f"got x {x.shape} vs w {state.w.shape}")
        # Per-stream state must be batched too: an unbatched (M,) membrane
        # or trace would silently broadcast ONE user's state across every
        # stream (and, for M == B, transpose the axes without an error).
        for name, arr, want in (("v", state.v, (b, m)),
                                ("trace_pre", state.trace_pre, (b, n)),
                                ("trace_post", state.trace_post, (b, m))):
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"fleet mode needs {name} of shape {want} matching "
                    f"w (B, N, M) = {state.w.shape}; got {name} "
                    f"{tuple(arr.shape)}")
        if active is not None and tuple(active.shape) != (b,):
            raise ValueError(
                f"active slot mask must have shape (B,) = ({b},); got "
                f"{tuple(active.shape)}")
        # an unbatched (M,) teach broadcasts to every stream inside the
        # fleet wrappers (ref.dual_engine_fleet_step / the Pallas wrapper)
        kw["active"] = active
    elif active is not None:
        raise ValueError(
            "active slot masks are a fleet-mode (w (B, N, M)) contract; "
            f"got w {state.w.shape} with an active mask")
    if telemetry:
        if not fleet:
            raise ValueError(
                "telemetry is a fleet-mode (w (B, N, M)) contract: per-slot "
                f"rows need a leading stream rank; got w {state.w.shape}")
        kw["telemetry"] = True

    # Select the backend function; the quant variants take the per-tile
    # weight scale as an extra positional between w and theta.
    if qc is not None:
        w_scale = (state.w_scale if state.w_scale is not None
                   else jnp.float32(qc.w_scale))
        scale_args = (w_scale,)
        fn = {("xla", False): _ref.dual_engine_step_q,
              ("xla", True): _ref.dual_engine_fleet_step_q,
              ("pallas", False): _kernel.dual_engine_step_q_pallas,
              ("pallas", True): _kernel.dual_engine_fleet_step_q_pallas}
    else:
        scale_args = ()
        fn = {("xla", False): _ref.dual_engine_step,
              ("xla", True): _ref.dual_engine_fleet_step,
              ("pallas", False): _kernel.dual_engine_step_pallas,
              ("pallas", True): _kernel.dual_engine_fleet_step_pallas}
    if impl == "xla":
        fn = fn[("xla", fleet)]
        res = fn(
            x, state.w, *scale_args, state.theta, state.v, state.trace_pre,
            state.trace_post, teach=teach, **kw)
    else:
        # The Pallas kernels are rank-(B, N); promote unbatched state to B=1.
        unbatched = not fleet and x.ndim == 1
        up = (lambda a: a[None]) if unbatched else (lambda a: a)
        fn = fn[("pallas", fleet)]
        res = fn(
            up(x), state.w, *scale_args, state.theta, up(state.v),
            up(state.trace_pre), up(state.trace_post),
            teach=None if teach is None else up(teach),
            block_m=params.block_m, interpret=(impl == "pallas-interpret"),
            **kw)
        if unbatched:
            res = (res[0][0], res[1][0], res[2][0]) + tuple(res[3:])
    spikes, v, tpost, w = res[:4]

    new_state = dataclasses.replace(state, w=w, v=v, trace_post=tpost)
    out = spikes if params.spiking else v
    if active is not None and not params.spiking:
        # The readout's output IS the membrane; the state gate correctly
        # freezes v to its OLD value for inactive slots, but the output
        # contract ("inactive events are zero") must hold for readout
        # layers too — a pooled consumer must never see a stale membrane.
        out = jnp.where(active.astype(bool)[:, None], out,
                        jnp.zeros_like(out))
    if not telemetry:
        return new_state, out
    # Normalize the raw per-slot sums into per-neuron / per-synapse means.
    b, n, m = state.w.shape
    raw = res[4]
    tel = FleetTelemetry(
        spike_rate=raw[:, 0] / m,
        mean_abs_dw=raw[:, 1] / (n * m),
        sat_frac=raw[:, 2] / m,
        occupancy=_occupancy(active, b))
    return new_state, out, tel


def _validate_rollout_params(params) -> None:
    """Rollout params must agree on everything a single fused window shares
    (dynamics scalars + datapath); only spiking/plastic may vary by layer."""
    p0 = params[0]
    for i, p in enumerate(params):
        for f in ("tau_m", "v_th", "v_reset", "trace_decay", "w_clip",
                  "quant"):
            if getattr(p, f) != getattr(p0, f):
                raise ValueError(
                    f"rollout fuses all layers into one window and needs "
                    f"uniform EngineParams.{f}; layer {i} has "
                    f"{getattr(p, f)!r} vs layer 0's {getattr(p0, f)!r}")


def rollout(state: NetworkState, theta, drives: jax.Array, *,
            params, impl: str = "xla",
            teach: Optional[jax.Array] = None,
            active: Optional[jax.Array] = None,
            seed: Optional[jax.Array] = None,
            unroll_k: int = 1, block_b: int = 8,
            telemetry: bool = False
            ) -> tuple[NetworkState, jax.Array]:
    """K fused timesteps of the WHOLE layer stack (the rollout megakernel).

    The time-fused analogue of calling `layer_step` K * num_layers times:
    on the Pallas backends the entire window executes as ONE `pallas_call`
    (kernels/plasticity/fused) with membranes, traces, the active-slot
    mask, and the weight tiles VMEM-resident across all K steps; on
    ``impl="xla"`` a `lax.scan` over the per-step `layer_step` oracle
    defines the semantics the kernel is pinned against bit-for-bit.

    Args:
      state:  `NetworkState` — shared weights (N, M) (activations unbatched
              or batched (B, ·)) or a fleet pool (B, N, M).
      theta:  per-layer packed (4, N_i, M_i) rules (entries may be None for
              non-plastic layers).
      drives: time-major input window — (K, N0), (K, B, N0); int32 fixed
              point when the params carry a QuantConfig, float otherwise.
      params: per-layer `EngineParams` sequence (or a single EngineParams
              applied to every layer); must agree on the dynamics scalars
              and quant mode (see `_validate_rollout_params`).
      teach:  optional teaching current for the LAST layer.  Rank selects
              the semantics: ``teach.ndim == drives.ndim`` is a per-step
              (K, ·, M) window; ``drives.ndim - 1`` is one held signal
              broadcast over the window (the classify_window protocol).
      active: fleet-only (B,) slot mask, constant across the window
              (admissions/evictions happen BETWEEN windows); inactive
              slots are bit-frozen for all K steps.
      seed:   fixed-point mode — base step counter (scalar, or (B,)
              per-session counters in fleet mode); step k draws its
              stochastic round from ``fold_seed(seed + k, layer)``, the
              exact per-step sequence.  Defaults to ``state.t``.
      unroll_k: Pallas time-loop chunking (0 / >= K = full unroll).  Quant
              mode computes identical bits at every setting; float mode is
              bit-pinned against the oracle at the default 1 for
              controller-scale layers and drifts by ULPs when several
              steps share one unrolled body or layers are wide (~64+) —
              FMA-contraction freedom, see kernels/plasticity/fused.  The
              xla oracle ignores it.
      block_b: fleet streams per Pallas grid program.
      telemetry: fleet-only STATIC flag — emit an `obs.FleetTelemetry` of
              per-slot WINDOW means as a third result: spike_rate/sat_frac
              accumulate per step inside the window (averaged over steps
              and layers), mean_abs_dw is the NET weight motion
              ``|w_end - w_start| / (N*M) / (K * n_plastic)`` — the
              activity measure that survives the fixed-point grid, and
              the one that costs one reduction per window rather than one
              per step.  Off-path traces stay byte-identical.

    Returns ``(new_state, outs)`` with outs (K, ·, M_last) and
    ``new_state.t = state.t + K``; with ``telemetry=True``:
    ``(new_state, outs, FleetTelemetry)``.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if isinstance(params, EngineParams):
        params = [params] * state.num_layers
    params = list(params)
    if len(params) != state.num_layers:
        raise ValueError(f"need one EngineParams per layer "
                         f"({state.num_layers}), got {len(params)}")
    _validate_rollout_params(params)
    theta = list(theta)
    if len(theta) != state.num_layers:
        raise ValueError(f"need one theta entry per layer "
                         f"({state.num_layers}; None for non-plastic), "
                         f"got {len(theta)}")
    qc = params[0].quant
    fleet = state.w[0].ndim == 3
    if drives.ndim not in (2, 3):
        raise ValueError(f"drives must be (K, N0) or (K, B, N0); got "
                         f"{drives.shape}")
    if fleet and drives.ndim != 3:
        raise ValueError(f"fleet rollout needs drives (K, B, N0); got "
                         f"{drives.shape}")
    if active is not None and not fleet:
        raise ValueError("active slot masks are a fleet-mode contract")
    if telemetry and not fleet:
        raise ValueError(
            "telemetry is a fleet-mode (w (B, N, M)) contract: per-slot "
            "rows need a leading stream rank")
    k_steps = drives.shape[0]
    if k_steps < 1:
        raise ValueError("rollout needs K >= 1 timesteps")
    if fleet:
        b = state.w[0].shape[0]
        if drives.shape[1] != b:
            raise ValueError(f"fleet rollout needs drives (K, B, N0) with "
                             f"B = {b}; got {drives.shape}")
        if active is not None and tuple(active.shape) != (b,):
            raise ValueError(f"active slot mask must have shape ({b},); "
                             f"got {tuple(active.shape)}")
    if qc is not None:
        # same loud contracts as layer_step (the Pallas path skips it)
        if params[0].tau_m != qc.tau_m:
            raise ValueError(
                f"quant mode implements tau_m = 2**tau_shift = {qc.tau_m}; "
                f"set EngineParams.tau_m to match (got {params[0].tau_m})")
        if abs(params[0].trace_decay - qc.decay) > 1e-9:
            raise ValueError(
                f"quant mode implements trace_decay = 1 - 2**-trace_shift "
                f"= {qc.decay}; set EngineParams.trace_decay to match "
                f"(got {params[0].trace_decay})")
        checks = [("w", state.w[0], jnp.int8), ("drives", drives, jnp.int32),
                  ("v", state.v[0], jnp.int32),
                  ("trace", state.trace[0], jnp.int32)]
        if teach is not None:
            checks.append(("teach", teach, jnp.int32))
        for name, arr, want in checks:
            if arr.dtype != want:
                raise ValueError(
                    f"quant rollout needs {name} of dtype "
                    f"{jnp.dtype(want).name} (build state via snn.init_state"
                    f"/quantize_state; quantize drives/teach with "
                    f"kernels.plasticity.quant.to_fixed); got {arr.dtype}")
    # teach rank disambiguation: same rank as drives => per-step window;
    # one less => held signal broadcast over the K steps.
    if teach is not None:
        if teach.ndim == drives.ndim - 1:
            teach = jnp.broadcast_to(teach[None], (k_steps, *teach.shape))
        elif teach.ndim != drives.ndim:
            raise ValueError(
                f"teach must be per-step (K, ..., M) of rank {drives.ndim} "
                f"or held of rank {drives.ndim - 1}; got {teach.shape}")
    base_seed = None
    if qc is not None:
        base_seed = (jnp.asarray(seed, jnp.int32) if seed is not None
                     else state.t.astype(jnp.int32))

    if impl == "xla":
        res = _rollout_xla(state, theta, drives, params, teach,
                           active, base_seed, telemetry=telemetry)
    else:
        res = _rollout_pallas(
            state, theta, drives, params, teach, active, base_seed,
            unroll_k=unroll_k, block_b=block_b,
            interpret=(impl == "pallas-interpret"), telemetry=telemetry)
    new_state, outs = res[0], res[1]
    new_state = dataclasses.replace(new_state, t=state.t + k_steps)
    if not telemetry:
        return new_state, outs
    return new_state, outs, res[2]


def _rollout_xla(state, theta, drives, params, teach, active, base_seed,
                 *, telemetry=False):
    """Scanned per-step oracle: the semantic ground truth for the fused
    kernel (body = snn.timestep's dataflow, layer steps via `layer_step`).

    With ``telemetry`` the scan carry grows a (B, 2) [spike, saturation]
    accumulator mirroring the fused kernel's in-register one; the |dw|
    column is the NET window motion computed ONCE post-scan from the
    weight carry — the scan body never touches per-step weight deltas, so
    the telemetry variant adds two cheap reductions per step, not an
    O(B*N*M) pass.
    """
    qc = params[0].quant
    decay = params[0].trace_decay
    n_layers = state.num_layers
    ks = jnp.arange(drives.shape[0], dtype=jnp.int32)
    xs = (drives, ks) if teach is None else (drives, teach, ks)

    def _event_units(out, spiking):
        """|events| in event units from a layer's gated output (the readout
        membrane maps back through its event nonlinearity; inactive slots'
        zeroed outputs stay zero under both)."""
        if qc is not None:
            ev = out if spiking else jnp.clip(out, -qc.one, qc.one)
            return jnp.abs(ev).astype(jnp.float32) / qc.one
        return jnp.abs(out if spiking else jnp.tanh(out))

    def body(carry, inp):
        if telemetry:
            w, v, tr, acc = carry
        else:
            (w, v, tr), acc = carry, None
        if teach is None:
            x, k = inp
            teach_k = None
        else:
            x, teach_k, k = inp
        w, v, tr = list(w), list(v), list(tr)
        if qc is not None:
            tr0_new = _Q.trace_update_q(tr[0], x, qc)
        else:
            tr0_new = _P.update_trace(tr[0], x, decay)
        if active is not None:
            tr0_new = jnp.where(active.astype(bool)[:, None], tr0_new,
                                tr[0])
        tr[0] = tr0_new
        out = None
        for i in range(n_layers):
            layer = LayerState(
                w=w[i], v=v[i], trace_pre=tr[i], trace_post=tr[i + 1],
                theta=theta[i],
                w_scale=state.w_scale[i] if state.w_scale else None)
            layer, out = layer_step(
                layer, x, params=params[i], impl="xla",
                teach=teach_k if i == n_layers - 1 else None,
                active=active,
                seed=(None if base_seed is None
                      else _Q.fold_seed(base_seed + k, i)))
            w[i], v[i], tr[i + 1] = layer.w, layer.v, layer.trace_post
            if telemetry:
                m_i = out.shape[-1]
                ev_f = _event_units(out, params[i].spiking)
                if qc is not None:
                    sat = jnp.abs(layer.v) >= sat_threshold_q(
                        params[i].v_th, qc)
                else:
                    sat = jnp.abs(layer.v) >= sat_threshold(params[i].v_th)
                acc = acc + jnp.stack(
                    [jnp.sum(ev_f, axis=1) / m_i,
                     jnp.sum(sat.astype(jnp.float32), axis=1) / m_i],
                    axis=1)
            x = out
        new = (tuple(w), tuple(v), tuple(tr))
        return (new + (acc,) if telemetry else new), out

    carry0 = (state.w, state.v, state.trace)
    if telemetry:
        carry0 = carry0 + (jnp.zeros((state.w[0].shape[0], 2), jnp.float32),)
    carry, outs = jax.lax.scan(body, carry0, xs)
    w, v, tr = carry[0], carry[1], carry[2]
    new_state = dataclasses.replace(state, w=w, v=v, trace=tr)
    if not telemetry:
        return new_state, outs

    k_steps = drives.shape[0]
    kl = float(k_steps * n_layers)
    acc = carry[3]
    spike_rate, sat_frac = acc[:, 0] / kl, acc[:, 1] / kl
    plast = [i for i in range(n_layers)
             if params[i].plastic and theta[i] is not None]
    if plast:
        dw_sum = jnp.zeros_like(spike_rate)
        for i in plast:
            n_i, m_i = state.w[i].shape[-2], state.w[i].shape[-1]
            d = jnp.abs(w[i].astype(jnp.int32)
                        - state.w[i].astype(jnp.int32)).astype(jnp.float32) \
                if qc is not None else jnp.abs(w[i] - state.w[i])
            per_slot = jnp.sum(d, axis=(1, 2))
            if qc is not None:
                sc = (state.w_scale[i] if state.w_scale
                      else jnp.float32(qc.w_scale))
                per_slot = per_slot * jnp.asarray(sc).reshape(-1)
            dw_sum = dw_sum + per_slot / (n_i * m_i)
        mean_dw = dw_sum / float(k_steps * len(plast))
    else:
        mean_dw = jnp.zeros_like(spike_rate)
    occ = _occupancy(active, state.w[0].shape[0])
    gate = occ if active is not None else jnp.ones_like(occ)
    tel = FleetTelemetry(spike_rate=spike_rate * gate,
                         mean_abs_dw=mean_dw * gate,
                         sat_frac=sat_frac * gate,
                         occupancy=occ)
    return new_state, outs, tel


def _rollout_pallas(state, theta, drives, params, teach, active, base_seed,
                    *, unroll_k, block_b, interpret, telemetry=False):
    """Dispatch the fused megakernel; promotes unbatched shared state to
    B=1 (the kernel is rank-(B, ·) like the per-step Pallas wrappers)."""
    qc = params[0].quant
    fleet = state.w[0].ndim == 3
    unbatched = not fleet and drives.ndim == 2
    up = (lambda a: a[None]) if unbatched else (lambda a: a)
    up_t = (lambda a: a[:, None]) if unbatched else (lambda a: a)
    p0 = params[0]
    thetas = [theta[i] if params[i].plastic else None
              for i in range(state.num_layers)]
    scales = None
    if qc is not None:
        scales = [state.w_scale[i] if state.w_scale
                  else jnp.float32(qc.w_scale)
                  for i in range(state.num_layers)]
    res = _fused.rollout_pallas(
        up_t(drives), state.w, thetas,
        tuple(up(x) for x in state.v), tuple(up(x) for x in state.trace),
        spiking=tuple(p.spiking for p in params),
        plastic=tuple(p.plastic and thetas[i] is not None
                      for i, p in enumerate(params)),
        tau_m=p0.tau_m, v_th=p0.v_th, v_reset=p0.v_reset,
        trace_decay=p0.trace_decay, w_clip=p0.w_clip, qcfg=qc,
        scales=scales, seed=base_seed,
        teach=None if teach is None else up_t(teach), active=active,
        telemetry=telemetry,
        block_b=block_b, unroll_k=unroll_k, interpret=interpret)
    outs, w, v, tr = res[:4]
    if unbatched:
        outs = outs[:, 0]
        v = tuple(x[0] for x in v)
        tr = tuple(x[0] for x in tr)
    new_state = dataclasses.replace(state, w=w, v=v, trace=tr)
    if not telemetry:
        return new_state, outs
    raw = res[4]                       # finalized, already gated (B, 3)
    tel = FleetTelemetry(spike_rate=raw[:, 0], mean_abs_dw=raw[:, 1],
                         sat_frac=raw[:, 2],
                         occupancy=_occupancy(active, raw.shape[0]))
    return new_state, outs, tel


# ---- sharding-transparent fleet dispatch (multi-device slot pools) ---------


def fleet_spmd(fn, mesh, in_axes, out_axes, axis_name: str = "data"):
    """Wrap a fleet-mode function in `shard_map` over the slot axis.

    The fleet tensors are slot-major and slot rows are mutually independent
    (the whole point of fleet mode), so a pool of B slots on a D-device mesh
    is pure data parallelism: every device runs the SAME program — the same
    `layer_step`/`rollout` lowering, the same Pallas kernel body — on its
    B/D local slots, with zero cross-device collectives in the hot path.
    Because the per-slot math is untouched, a sharded pool is bit-identical
    to the unmeshed pool (tests/test_distributed.py pins it, float and int8,
    xla and pallas-interpret).

    `shard_map` rather than sharded jit because GSPMD has no partitioning
    rule for `pallas_call` — manual SPMD is what lets the megakernel run
    per-shard unchanged.  ``check_rep=False`` for the same reason (Pallas
    calls carry no replication rule).

    Args:
      fn:       positional-argument function over fleet pytrees.
      mesh:     a Mesh with `axis_name` (e.g. `distributed.sharding.
                fleet_mesh()`).
      in_axes:  one entry per positional argument: an int — the slot axis
                every leaf of that argument carries (0 for ``(B, ...)``
                state, 1 for time-major ``(K, B, ...)`` windows) — or None
                for replicated inputs (scalars, shared rule state).
      out_axes: same, per output; every output must be slot-mapped (an
                int): with ``check_rep=False`` a replicated output cannot
                be verified, so compute pool-global outputs OUTSIDE the
                wrapped call.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    def spec(ax, kind):
        if ax is None:
            if kind == "out":
                raise ValueError(
                    "fleet_spmd outputs must be slot-mapped (int axis); "
                    "compute replicated outputs outside the wrapped fn")
            return PartitionSpec()
        return PartitionSpec(*((None,) * ax), axis_name)

    return shard_map(
        fn, mesh=mesh,
        in_specs=tuple(spec(a, "in") for a in in_axes),
        out_specs=tuple(spec(a, "out") for a in out_axes),
        check_rep=False)
