"""PlasticEngine: the backend-dispatched fused layer step (product hot path).

One `layer_step` = one SNN timestep for ONE synaptic layer, running the
Forward Engine (psum matmul -> neuron dynamics -> trace update) and the
Plasticity Engine (four-term dw, weights rewritten in place) as a single
fused program — the FireFly-P dual-engine overlap (Secs. III-B/C).

Every consumer of the rule — `core/snn.timestep`, the adaptation loops, the
LM plastic adapter, serving, examples, and benchmarks — routes layer steps
through this module, so the Pallas kernel is the single source of truth for
the hot path rather than a benchmark artifact.

Backends (`impl`):

  * ``"xla"``              — pure-jnp oracle (kernels/plasticity/ref).  What
                             CPU runs and dry-runs lower; bit-stable with the
                             historical hand-rolled jnp layer loop.
  * ``"pallas"``           — the fused Pallas TPU kernel
                             (kernels/plasticity/kernel).
  * ``"pallas-interpret"`` — same kernel body executed by the Pallas
                             interpreter; validates the TPU program on CPU.

`layer_step` accepts unbatched ``(N,)`` or batched ``(B, N)`` state.  Shared
weights batch-average the update (delta_w semantics); per-sample plastic
networks (e.g. the per-request LM adapter) `jax.vmap` `layer_step` with
``in_axes=(LayerState(w=0, v=0, trace_pre=0, trace_post=0, theta=None), 0)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import ref as _ref

IMPLS = ("xla", "pallas", "pallas-interpret")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerState:
    """State slice the dual-engine step reads and rewrites for one layer.

    ``trace_pre`` is the *already-updated* presynaptic trace for the current
    timestep (the predecessor layer's Trace Update Unit runs upstream);
    ``trace_post`` is the previous timestep's postsynaptic trace, which
    `layer_step` advances and returns.  ``theta`` is the packed
    ``(4, n_pre, n_post)`` rule; ``None`` for non-plastic layers.
    """

    w: jax.Array                        # (N, M) synaptic weights
    v: jax.Array                        # (M,) | (B, M) membrane potential
    trace_pre: jax.Array                # (N,) | (B, N)
    trace_post: jax.Array               # (M,) | (B, M)
    theta: Optional[jax.Array] = None   # (4, N, M) packed rule coefficients


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetworkState:
    """Whole-network state: per-layer weights/membranes, per-population traces.

    Replaces the historical raw ``{"w": [...], "v": [...], "trace": [...]}``
    dict; registered as a pytree so it threads through jit/scan/vmap.
    ``trace`` has ``num_layers + 1`` entries — ``trace[i]`` is layer i's
    presynaptic population (``trace[0]`` is the input drive's trace).
    """

    w: Tuple[jax.Array, ...]
    v: Tuple[jax.Array, ...]
    trace: Tuple[jax.Array, ...]
    t: jax.Array

    @property
    def num_layers(self) -> int:
        return len(self.w)

    def layer(self, i: int, theta=None) -> LayerState:
        """View layer i as a LayerState (traces must be current-timestep)."""
        return LayerState(w=self.w[i], v=self.v[i], trace_pre=self.trace[i],
                          trace_post=self.trace[i + 1], theta=theta)


@dataclasses.dataclass(frozen=True)
class EngineParams:
    """Static per-layer parameters of the fused step (hashable; jit-static)."""

    tau_m: float = 2.0
    v_th: float = 1.0
    v_reset: float = 0.0
    trace_decay: float = 0.8
    w_clip: float = 4.0
    plastic: bool = True
    spiking: bool = True        # False => leaky readout (event = tanh(V))
    block_m: int = 128          # Pallas postsynaptic tile width


def layer_step(state: LayerState, x: jax.Array, *,
               params: EngineParams = EngineParams(),
               impl: str = "xla",
               teach: Optional[jax.Array] = None
               ) -> tuple[LayerState, jax.Array]:
    """One fused forward+plasticity step for one layer.

    Args:
      state: layer state; rewritten functionally (w, v, trace_post advance).
      x:     presynaptic events ``(N,)`` or ``(B, N)``.
      params: static engine parameters.
      impl:  ``"xla"`` | ``"pallas"`` | ``"pallas-interpret"``.
      teach: optional teaching current added to the psum ``(M,)``/``(B, M)``
             (supervised online learning on the output layer).

    Returns:
      ``(new_state, out)`` — ``out`` is the layer's output events: spikes for
      spiking layers, the membrane potential for the leaky readout.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    plastic = params.plastic and state.theta is not None
    kw = dict(tau_m=params.tau_m, v_th=params.v_th, v_reset=params.v_reset,
              trace_decay=params.trace_decay, w_clip=params.w_clip,
              plastic=plastic, spiking=params.spiking)

    if impl == "xla":
        spikes, v, tpost, w = _ref.dual_engine_step(
            x, state.w, state.theta, state.v, state.trace_pre,
            state.trace_post, teach=teach, **kw)
    else:
        # The Pallas kernel is rank-(B, N); promote unbatched state to B=1.
        unbatched = x.ndim == 1
        up = (lambda a: a[None]) if unbatched else (lambda a: a)
        spikes, v, tpost, w = _kernel.dual_engine_step_pallas(
            up(x), state.w, state.theta, up(state.v), up(state.trace_pre),
            up(state.trace_post),
            teach=None if teach is None else up(teach),
            block_m=params.block_m, interpret=(impl == "pallas-interpret"),
            **kw)
        if unbatched:
            spikes, v, tpost = spikes[0], v[0], tpost[0]

    new_state = dataclasses.replace(state, w=w, v=v, trace_post=tpost)
    out = spikes if params.spiking else v
    return new_state, out
