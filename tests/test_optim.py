"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import (adamw, clip_by_global_norm, compress_int8,
                         decompress_int8, ef_compress_update, global_norm,
                         init_ef_state, linear_warmup, sgd, warmup_cosine)


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = adamw(lr=0.1, weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 1e-3

    def test_master_weights_beat_bf16_underflow(self):
        """Tiny updates vanish in bf16 without a master copy."""
        params = {"x": jnp.ones((4,), jnp.bfloat16)}
        g = {"x": jnp.full((4,), 1e-4, jnp.float32)}
        for master in (False, True):
            opt = adamw(lr=1e-4, weight_decay=0.0, master_weights=master)
            state = opt.init(params)
            p = params
            for _ in range(50):
                p, state = opt.update(g, state, p)
            moved = float(jnp.abs(p["x"].astype(jnp.float32) - 1.0).max())
            if master:
                assert float(
                    jnp.abs(state.master["x"] - 1.0).max()) > 1e-4
            # bf16 storage may or may not move; master path must track
        assert state.master is not None

    def test_bf16_moments(self):
        opt = adamw(lr=0.1, moment_dtype="bfloat16")
        params = {"x": jnp.asarray([1.0])}
        state = opt.init(params)
        assert state.mu["x"].dtype == jnp.bfloat16
        g = {"x": jnp.asarray([0.5])}
        _, state = opt.update(g, state, params)
        assert state.nu["x"].dtype == jnp.bfloat16

    def test_sgd_momentum(self):
        opt = sgd(lr=0.05, momentum=0.9)
        params = jnp.asarray([4.0])
        state = opt.init(params)
        for _ in range(200):
            g = 2 * params
            params, state = opt.update(g, state, params)
        assert abs(float(params[0])) < 5e-2


class TestClip:
    def test_clip_rescales(self):
        tree = {"a": jnp.asarray([3.0, 4.0])}       # norm 5
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert abs(float(norm) - 5.0) < 1e-5
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_noop_below_threshold(self):
        tree = {"a": jnp.asarray([0.3])}
        clipped, _ = clip_by_global_norm(tree, 1.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3], rtol=1e-6)


class TestSchedules:
    def test_warmup_cosine_shape(self):
        fn = warmup_cosine(1.0, 10, 100, final_frac=0.1)
        assert float(fn(jnp.asarray(0))) < 0.2
        assert abs(float(fn(jnp.asarray(10))) - 1.0) < 0.1
        assert float(fn(jnp.asarray(100))) <= 0.11

    def test_linear_warmup_monotone(self):
        fn = linear_warmup(1.0, 5)
        vals = [float(fn(jnp.asarray(i))) for i in range(8)]
        assert vals == sorted(vals)
        assert vals[-1] == 1.0


class TestCompression:
    @given(st.integers(0, 2**32 - 1), st.floats(0.01, 100.0))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_error_bounded(self, seed, scale):
        g = scale * jax.random.normal(jax.random.PRNGKey(seed), (256,))
        q, s = compress_int8(g)
        assert q.dtype == jnp.int8
        err = jnp.abs(decompress_int8(q, s) - g).max()
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_converges(self):
        """With EF, the *accumulated* compressed stream tracks the true
        gradient sum (the residual stays bounded)."""
        key = jax.random.PRNGKey(0)
        g_true = jax.random.normal(key, (64,)) * 0.01
        ef = jnp.zeros((64,))
        acc = jnp.zeros((64,))
        for i in range(50):
            q, s, ef = ef_compress_update(g_true, ef)
            acc = acc + decompress_int8(q, s)
        total_err = jnp.abs(acc - 50 * g_true).max()
        # without EF the bias would grow linearly; with EF it stays ~1 quantum
        assert float(total_err) <= float(jnp.abs(g_true).max()) * 5

    def test_init_ef_state_shapes(self):
        grads = {"w": jnp.ones((3, 3), jnp.bfloat16)}
        ef = init_ef_state(grads)
        assert ef["w"].shape == (3, 3) and ef["w"].dtype == jnp.float32
