"""Jit'd public wrapper for attention. impl: "xla" (oracle) | "pallas"."""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention import kernel as _kernel
from repro.kernels.attention import ref as _ref
from repro.kernels.attention import xla_flash as _xla_flash


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "kv_len", "impl", "interpret",
                     "block_q", "block_kv"))
def attention(q, k, v, *, causal: bool = True, scale=None, kv_len=None,
              impl: str = "xla", interpret: bool = False,
              block_q: int = 128, block_kv: int = 128):
    if impl == "pallas":
        return _kernel.flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, kv_len=kv_len,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
    if impl == "xla_flash":
        return _xla_flash.blocked_attention(
            q, k, v, causal=causal, scale=scale, kv_len=kv_len)
    return _ref.mha(q, k, v, causal=causal, scale=scale, kv_len=kv_len)
