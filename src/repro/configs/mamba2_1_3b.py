"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060]

vocab 50280 is not divisible by the 16-way model axis — the embedding
sharding falls back to replicated for that dim (sharding.py drops
non-dividing axes); the lm_head matmul stays model-sharded on d_inner.
Eligible for long_500k: decode state is O(1) per token."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    layout="ssm", sub_quadratic=True,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=256),
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=509,          # odd vocab, as in full (50280 % 16 != 0)
    layout="ssm", sub_quadratic=True, remat=False,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=16),
)
