"""Host-side metrics: counters/gauges/histograms + Prometheus/JSON export.

A deliberately small, dependency-free registry (the container bakes no
prometheus_client) with the exposition semantics monitoring stacks expect:

  * `Counter`   — monotonically increasing total (``_total`` suffix by
    convention): admissions, evictions, warm-cache hits, restores.
  * `Gauge`     — point-in-time value: pool occupancy, tokens/s, the fleet
    telemetry means.
  * `Histogram` — cumulative le-buckets + sum/count (Prometheus histogram
    exposition) plus a bounded reservoir of raw observations so the
    benchmarks can report true p50s: admit/evict/checkout/restore/decode
    latencies.

Every serving component owns a `MetricsRegistry` (SessionStore, each
SessionPool, launch/serve.py, the scenario harness) rather than mutating a
process-global singleton, so two pools in one process never alias counters;
`REGISTRY` exists as the default for one-off scripts.  Exporters:
`prometheus_text()` (text exposition format) and `snapshot()` (JSON-able
dict — the schema `benchmarks/serving_churn.py` reconciles against its own
event log and the CI obs-smoke job uploads as an artifact).

`phase(name)` annotates a host-side serving phase (admit, swap-in/out, pool
step, decode window) with `jax.profiler.TraceAnnotation`, so device
profiles attribute time to scheduling events; it degrades to a no-op
timer-only context when the profiler is unavailable.

`serve_metrics(registry, port)` exposes a registry over stdlib
`http.server` for scraping (`serve.py --metrics-port`): every metric holds
its own lock across its full export, so a scrape racing the serving thread
always sees a consistent (count, sum, buckets) triple.
"""
from __future__ import annotations

import json
import math
import threading
import time
from contextlib import contextmanager
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Iterable, Optional

# Default le-buckets: 100 us .. ~100 s in half-decade steps — spans warm
# admissions (sub-ms), disk restores (ms..tens of ms), and decode windows.
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
                   1.0, 3.0, 10.0, 30.0, 100.0)
_RESERVOIR = 4096      # raw observations kept per histogram (for percentiles)


class Counter:
    """Monotonic counter (increase-only)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: cannot decrease "
                             f"(inc({amount}))")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Point-in-time value (set/add)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Cumulative-bucket histogram + bounded raw reservoir for percentiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._raw: list = []
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            i = 0
            while i < len(self.buckets) and value > self.buckets[i]:
                i += 1
            self._counts[i] += 1
            self._sum += value
            self._count += 1
            if len(self._raw) < _RESERVOIR:
                self._raw.append(value)

    @contextmanager
    def time(self):
        """Observe the wall-clock duration of the with-block (seconds)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(time.perf_counter() - t0)

    def _export(self) -> tuple:
        """One consistent (counts, sum, count, raw) copy under the lock —
        the only way readers see this histogram, so a scrape racing
        `observe` never mixes a new count with an old sum."""
        with self._lock:
            return list(self._counts), self._sum, self._count, \
                list(self._raw)

    @staticmethod
    def _pct(raw: list, p: float) -> float:
        if not raw:
            return 0.0
        s = sorted(raw)
        k = min(len(s) - 1, max(0, int(math.ceil(p / 100.0 * len(s))) - 1))
        return s[k]

    @property
    def count(self) -> int:
        return self._export()[2]

    @property
    def sum(self) -> float:
        return self._export()[1]

    @property
    def mean(self) -> float:
        _, tot, n, _ = self._export()
        return tot / n if n else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100] from the raw reservoir (exact while it fits)."""
        return self._pct(self._export()[3], p)

    def snapshot(self) -> dict:
        counts, tot, n, raw = self._export()
        cum, out = 0, {}
        for le, c in zip(self.buckets, counts):
            cum += c
            out[f"{le:g}"] = cum
        return {"type": self.kind, "count": n, "sum": tot,
                "mean": tot / n if n else 0.0, "p50": self._pct(raw, 50),
                "p99": self._pct(raw, 99), "buckets": out}


class MetricsRegistry:
    """Get-or-create registry of named metrics with stable export schema."""

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def timer(self, name: str, help: str = ""):
        """Context manager timing the with-block into histogram `name`."""
        return self.histogram(name, help).time()

    # ---- export ----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able {metric name -> typed snapshot} (stable schema)."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: m.snapshot() for m in metrics}

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (histograms as le-buckets)."""
        with self._lock:
            metrics = list(self._metrics.values())
        lines = []
        for m in sorted(metrics, key=lambda m: m.name):
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                counts, tot, n, _ = m._export()
                cum = 0
                for le, c in zip(m.buckets, counts):
                    cum += c
                    lines.append(f'{m.name}_bucket{{le="{le:g}"}} {cum}')
                lines.append(f'{m.name}_bucket{{le="+Inf"}} {n}')
                lines.append(f"{m.name}_sum {tot:g}")
                lines.append(f"{m.name}_count {n}")
            else:
                lines.append(f"{m.name} {m.value:g}")
        return "\n".join(lines) + "\n"


REGISTRY = MetricsRegistry()     # default registry for one-off scripts


@contextmanager
def phase(name: str, histogram: Optional[Histogram] = None):
    """Annotate a serving phase for profilers (+ optional latency record).

    Wraps the block in `jax.profiler.TraceAnnotation(name)` so device
    profiles attribute time to scheduling events (admit, swap_in, swap_out,
    pool_step, decode_window); if a `Histogram` is given the block's
    wall-clock duration is observed into it.  Profiler-free environments
    degrade to the timer alone.
    """
    t0 = time.perf_counter()
    try:
        from jax.profiler import TraceAnnotation
        ctx = TraceAnnotation(name)
    except Exception:           # pragma: no cover - profiler unavailable
        ctx = None
    try:
        if ctx is not None:
            with ctx:
                yield
        else:                   # pragma: no cover
            yield
    finally:
        if histogram is not None:
            histogram.observe(time.perf_counter() - t0)


# ---- scrape endpoint --------------------------------------------------------


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Expose `registry` over HTTP on a daemon thread; returns the server.

    ``GET /metrics`` (or ``/``) serves `prometheus_text()`; ``GET
    /metrics.json`` serves the JSON `snapshot()`.  ``port=0`` binds an
    ephemeral port — read it back from ``server.server_address[1]``.  The
    thread is a daemon and never blocks shutdown; call ``server.shutdown()``
    for a deterministic stop (tests do).
    """

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):                          # noqa: N802 (stdlib API)
            path = self.path.split("?", 1)[0]
            if path in ("/", "/metrics"):
                body = registry.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif path == "/metrics.json":
                body = json.dumps(registry.snapshot(), sort_keys=True,
                                  indent=1).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):              # silence per-request spam
            pass

    server = ThreadingHTTPServer((host, int(port)), _Handler)
    server.daemon_threads = True
    thread = threading.Thread(target=server.serve_forever,
                              name="metrics-http", daemon=True)
    thread.start()
    return server
