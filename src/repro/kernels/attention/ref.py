"""Pure-jnp oracle: multi-head attention with GQA, causal masking, KV length.

Layouts: q (B, Sq, H, D); k/v (B, Skv, HKV, D); HKV divides H.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def mha(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
        kv_len: Optional[int] = None):
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5

    kr = jnp.repeat(k, g, axis=2)  # (B, Skv, H, D)
    vr = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale

    mask = jnp.ones((sq, skv), bool)
    if causal:
        # queries are the LAST sq positions of the kv sequence (decode-friendly)
        offset = skv - sq
        qi = jnp.arange(sq)[:, None] + offset
        ki = jnp.arange(skv)[None, :]
        mask = mask & (ki <= qi)
    if kv_len is not None:
        mask = mask & (jnp.arange(skv)[None, :] < kv_len)
    logits = jnp.where(mask[None, None], logits, -1e30)

    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
