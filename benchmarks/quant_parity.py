"""Float-vs-quant parity + int8 fleet-pool economics (the fixed-point engine).

Three sections, each asserted (nonzero exit on violation -> CI gate):

  1. BACKENDS   — the quantized controller rollout is BIT-identical between
                  impl="xla" and impl="pallas-interpret" (integer datapath:
                  exact reductions + elementwise float, see quant.py).
  2. CONTROL    — float32 vs fixed-point trajectories on the reacher and
                  direction control envs, BOTH run at the power-of-two
                  dynamics the hardware implements (trace decay 0.75,
                  tau_m 2), zero-start weights, same rule theta.  Reports
                  per-step action error and episode returns.  Documented
                  bounds (asserted): episode-MEAN |action| error stays
                  under MEAN_BOUND, and the task-level return gap stays
                  under RETURN_GAP of the float return's scale.  Pointwise
                  action error is reported but NOT gated: spiking
                  plasticity is chaotic (a one-quantum membrane difference
                  near threshold flips a spike and the trajectories
                  decorrelate), so a max-norm bound would be a coin flip —
                  the task-level agreement is the meaningful claim, and the
                  checked-in results show it at ~10-14%.
  3. FLEET      — pool bytes + fused steps/s for a float32 vs an int8 fleet
                  pool at B in {16, 64, 256} on the paper's (16, 128, 8)
                  controller: the int8 pool holds ~4x more resident
                  sessions per byte of HBM (weights dominate).

    PYTHONPATH=src python benchmarks/quant_parity.py [--smoke] [--impl ...]

Writes benchmarks/results/quant_parity.json (or *_smoke.json under --smoke
so CI never clobbers the checked-in full artifact; --out overrides).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import envs
from repro.core import snn

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# Documented bounds (asserted; see module docstring for what is NOT gated).
MEAN_BOUND = 0.75       # mean |action_f32 - action_quant| over the episode
RETURN_GAP = 0.5        # |R_f32 - R_quant| <= RETURN_GAP * max(|R_f32|, 1)
EARLY_STEPS = 5         # early window reported (informational only)


def _cfgs(obs_dim: int, act_dim: int, hidden: int, impl: str):
    qcfg = snn.quant_config(snn.SNNConfig(
        layer_sizes=(obs_dim, hidden, act_dim), timesteps=4, impl=impl))
    fcfg = dataclasses.replace(qcfg, quant=None)   # same power-of-two decays
    return fcfg, qcfg


def rollout(env, scfg, theta, task, key, steps: int):
    """Controller rollout collecting (actions, rewards) over `steps`."""
    k_env = key
    state = snn.init_state(scfg)
    est = env.reset(k_env, task)

    def step(carry, t):
        est, st = carry
        obs = env.observe(est)
        st, action = snn.controller_step(scfg, st, theta, obs)
        est, r = env.step(est, action)
        return (est, st), (action, r)

    (_, _), (actions, rewards) = jax.lax.scan(
        step, (est, state), jnp.arange(steps))
    return np.asarray(actions), float(rewards.sum())


def control_section(impl: str, hidden: int, steps: int):
    rows, failures = [], []
    for name in ("position", "direction"):   # position = the 2-link reacher
        env = envs.make(name, episode_len=steps)
        fcfg, qcfg = _cfgs(env.obs_dim, env.act_dim, hidden, impl)
        theta = snn.init_theta(qcfg, jax.random.PRNGKey(0), scale=0.1)
        task = env.train_tasks()[0]
        key = jax.random.PRNGKey(42)
        a_f, r_f = rollout(env, fcfg, theta, task, key, steps)
        a_q, r_q = rollout(env, qcfg, theta, task, key, steps)

        err = np.abs(a_f - a_q)
        mean_err = float(err.mean())
        gap = abs(r_f - r_q) / max(abs(r_f), 1.0)
        row = {"env": name, "steps": steps, "hidden": hidden,
               "max_abs_action_err": float(err.max()),
               "mean_abs_action_err": mean_err,
               "early_window_max_err": float(err[:EARLY_STEPS].max()),
               "early_window_steps": EARLY_STEPS,
               "return_float": r_f, "return_quant": r_q,
               "return_gap_rel": gap}
        rows.append(row)
        print(f"control[{name}] mean_err={mean_err:.3f} "
              f"R_f={r_f:.2f} R_q={r_q:.2f} gap={gap:.3f}")
        if mean_err > MEAN_BOUND:
            failures.append(f"{name}: mean action err {mean_err:.3f} "
                            f"> bound {MEAN_BOUND}")
        if gap > RETURN_GAP:
            failures.append(f"{name}: return gap {gap:.3f} "
                            f"> bound {RETURN_GAP}")
    return rows, failures


def backend_section(hidden: int, steps: int):
    """Quant rollout on xla vs pallas-interpret: BIT equality, always run."""
    env = envs.make("direction", episode_len=steps)
    results = {}
    for impl in ("xla", "pallas-interpret"):
        _, qcfg = _cfgs(env.obs_dim, env.act_dim, hidden, impl)
        theta = snn.init_theta(qcfg, jax.random.PRNGKey(0), scale=0.1)
        results[impl] = rollout(env, qcfg, theta, env.train_tasks()[0],
                                jax.random.PRNGKey(7), steps)
    a_x, r_x = results["xla"]
    a_p, r_p = results["pallas-interpret"]
    equal = bool(np.array_equal(a_x, a_p)) and r_x == r_p
    print(f"backends bitwise equal over {steps} control steps: {equal}")
    failures = [] if equal else [
        "quant rollout NOT bit-identical across xla/pallas-interpret"]
    return {"impls": ["xla", "pallas-interpret"], "steps": steps,
            "bitwise_equal": equal}, failures


def fleet_section(impl: str, batches, iters: int):
    """Pool bytes + fused pool steps/s, float vs int8, on (16, 128, 8)."""
    rows = []
    for b in batches:
        fcfg, qcfg = _cfgs(16, 8, 128, impl)
        theta = snn.init_theta(qcfg, jax.random.PRNGKey(0), scale=0.05)
        drive = jax.random.normal(jax.random.PRNGKey(b), (b, 16))
        seeds = jnp.zeros((b,), jnp.int32)
        row = {"batch": b}
        for tag, cfg in (("float", fcfg), ("quant", qcfg)):
            pool = snn.init_state(cfg, batch=b, fleet=True)
            row[f"{tag}_pool_bytes"] = int(
                sum(leaf.nbytes for leaf in jax.tree.leaves(pool)))

            fn = jax.jit(lambda st, d, sd, cfg=cfg: snn.timestep(
                cfg, st, theta, d, seed=sd))
            pool, out = fn(pool, drive, seeds)     # compile + warm-up
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(iters):
                pool, out = fn(pool, drive, seeds)
            jax.block_until_ready(out)
            row[f"{tag}_steps_per_s"] = iters / (time.perf_counter() - t0)
        row["bytes_ratio"] = row["float_pool_bytes"] / row["quant_pool_bytes"]
        rows.append(row)
        print(f"fleet B={b}: bytes {row['float_pool_bytes']} -> "
              f"{row['quant_pool_bytes']} ({row['bytes_ratio']:.2f}x), "
              f"steps/s {row['float_steps_per_s']:.1f} float / "
              f"{row['quant_steps_per_s']:.1f} quant")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="engine backend for the control/fleet sections "
                         "(the backend-parity section always runs both "
                         "xla and pallas-interpret)")
    ap.add_argument("--hidden", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        suffix = "" if args.impl == "xla" else "_" + args.impl.replace("-",
                                                                       "_")
        name = (f"quant_parity_smoke{suffix}.json" if args.smoke
                else f"quant_parity{suffix}.json")
        args.out = os.path.join(RESULTS, name)

    hidden = args.hidden or (32 if args.smoke else 128)
    steps = 20 if args.smoke else 150
    batches = [4, 8] if args.smoke else [16, 64, 256]
    iters = 3 if args.smoke else 20
    bk_steps = 6 if args.smoke else 20

    t0 = time.time()
    backend_row, fail_b = backend_section(hidden, bk_steps)
    control_rows, fail_c = control_section(args.impl, hidden, steps)
    fleet_rows = fleet_section(args.impl, batches, iters)

    out = {"impl": args.impl, "smoke": bool(args.smoke),
           "bounds": {"mean_abs_action_err": MEAN_BOUND,
                      "return_gap_rel": RETURN_GAP},
           "backends": backend_row, "control": control_rows,
           "fleet": fleet_rows}
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    failures = fail_b + fail_c
    print(f"\nquant_parity done in {time.time() - t0:.0f}s; "
          f"{len(failures)} bound violations: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
