"""LM decode pool under mixed occupancy + the windowed resume contract.

Pins, mirroring tests/test_serving.py for the LM path:

  1. Mixed occupancy: vacant slots during LM decode are TRUE no-ops (the
     whole session row — KV/SSM cache, adapter fast weights, index, pending
     token — bit-frozen), and an active stream's greedy tokens and final
     session are invariant to neighbour churn, on xla and pallas-interpret,
     float32 and int8 adapter pools.
  2. `decode_window` (the plastic.decode_rollout route) is bit-identical to
     K sequential `step` calls on the same tokens — same cache writes, same
     adapter plasticity, same stochastic-round stream in quant mode.
  3. Resume bit-identity ACROSS a rollout-window boundary: evict ->
     persist -> displacement by a rival -> re-admit into a different slot
     between two decode windows leaves the second window's logits and the
     final session bit-equal to an uninterrupted run.
  4. `launch/serve.py`'s scheduler-admit path: the AdapterPool round-trips
     through a durable on-disk SessionStore bit-exactly, and resumed
     sessions keep learning with cumulative step counters.
  5. COMPILE AUDIT: `compiled_programs()` pins the exact per-entry-point
     executable counts after a canonical serve sequence — one program per
     op (per window length for the windowed path, per prompt length for
     prefill), telemetry variants one each, and ONLY the entry point whose
     shape legitimately changed may grow.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import factory
from repro.serving import AdapterPool, LMScheduler, SessionStore

IMPLS = ["xla", "pallas-interpret"]
DATAPATHS = ["float32", "int8"]
# the full matrix where the satellite demands it (mixed occupancy); a
# cheaper diagonal elsewhere — the benchmark sweeps the whole cube
DIAG = [("xla", "float32"), ("xla", "int8"), ("pallas-interpret", "int8")]

LAYOUT_ARCH = {"dense": "qwen3-4b", "ssm": "mamba2-1.3b",
               "moe": "deepseek-moe-16b"}


def _model(layout, impl, datapath, neurons=8):
    cfg = factory.build(LAYOUT_ARCH[layout], smoke=True).cfg
    if cfg.moe is not None:
        # capacity >= every routable token: cross-row capacity coupling
        # inert, so per-stream bit-identity is well-defined (DESIGN.md
        # §Arch-applicability)
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    cfg = cfg.with_(plastic_adapter=True, adapter_neurons=neurons,
                    adapter_impl=impl, adapter_quant=(datapath == "int8"))
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params["adapter"]["scale"] = jnp.float32(0.5)
    return model, params


def _prompt(uid, n, vocab):
    rng = np.random.RandomState(abs(hash(uid)) % (2 ** 31))
    return rng.randint(0, vocab, size=n).astype(np.int32)


def _np(tree):
    return jax.tree.map(np.asarray, tree)


def _assert_trees_equal(a, b):
    jax.tree.map(np.testing.assert_array_equal, a, b)


class TestMixedOccupancy:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_churn_invariance_and_vacant_freeze(self, impl, datapath):
        """An active stream's trajectory is invariant to neighbours
        admitting/evicting around it, and a vacant slot's entire session
        row is bit-unchanged by pool steps."""
        model, params = _model("dense", impl, datapath)
        vocab = model.cfg.vocab
        # reference: the stream decodes alone
        ref = LMScheduler(model, params, slots=3, max_len=24)
        ref.admit_prompt("keep", _prompt("keep", 6, vocab))
        ref_toks = [ref.step()["keep"] for _ in range(8)]
        ref_sess = _np(ref.session_view("keep"))

        # churn: a rival is admitted and evicted around every early step
        churn = LMScheduler(model, params, slots=3, max_len=24)
        churn.admit_prompt("keep", _prompt("keep", 6, vocab))
        toks = []
        for t in range(5):
            churn.admit_prompt(f"r{t}", _prompt(f"r{t}", 6, vocab))
            toks.append(churn.step()["keep"])
            churn.evict(f"r{t}")
        # the rivals' slot is now vacant: its row must be bit-frozen (not
        # just ignored) across further decode steps
        vslot = jnp.int32(1)
        vacant_before = _np(churn._take(churn.pool, vslot))
        for _ in range(3):
            toks.append(churn.step()["keep"])
        _assert_trees_equal(vacant_before, _np(churn._take(churn.pool,
                                                           vslot)))
        assert toks == ref_toks
        _assert_trees_equal(ref_sess, _np(churn.session_view("keep")))


class TestWindowedDecode:
    @pytest.mark.parametrize("impl,datapath", DIAG)
    def test_window_equals_sequential_steps(self, impl, datapath):
        """decode_window(K) == K step() calls, bitwise: tokens, pending
        token, and every session leaf (cache rows, adapter W_fast/traces,
        per-session counter — the quant dither stream included)."""
        model, params = _model("ssm", impl, datapath)
        vocab, k = model.cfg.vocab, 3
        a = LMScheduler(model, params, slots=2, max_len=16)
        a.admit_prompt("u", _prompt("u", 5, vocab))
        first = a.pending("u")
        seq_toks = [a.step()["u"] for _ in range(k)]
        sess_a = _np(a.session_view("u"))

        b = LMScheduler(model, params, slots=2, max_len=16)
        b.admit_prompt("u", _prompt("u", 5, vocab))
        window = np.array([first] + seq_toks[:-1], np.int32)
        logits = np.asarray(b.decode_window({"u": window})["u"])
        assert logits.shape == (k, vocab)
        assert [int(t) for t in logits.argmax(-1)] == seq_toks
        assert b.pending("u") == seq_toks[-1]
        _assert_trees_equal(sess_a, _np(b.session_view("u")))

    @pytest.mark.parametrize("impl,datapath", DIAG)
    def test_resume_across_window_boundary(self, impl, datapath):
        """Evict -> persist (archive) -> displacement -> re-admit into a
        DIFFERENT slot between two rollout windows: the second window's
        logits and the final session are bit-equal to an uninterrupted
        run."""
        model, params = _model("dense", impl, datapath)
        vocab, k = model.cfg.vocab, 3
        prompt = _prompt("u", 5, vocab)
        forced = _prompt("forced", 2 * (k - 1), vocab)

        ref = LMScheduler(model, params, slots=3, max_len=24,
                          store=SessionStore())
        ref.admit_prompt("u", prompt)
        w1 = np.concatenate([[ref.pending("u")], forced[:k - 1]]
                            ).astype(np.int32)
        ref.decode_window({"u": w1})
        w2 = np.concatenate([[ref.pending("u")], forced[k - 1:]]
                            ).astype(np.int32)
        ref_logits = np.asarray(ref.decode_window({"u": w2})["u"])
        ref_sess = _np(ref.session_view("u"))

        s = LMScheduler(model, params, slots=3, max_len=24,
                        store=SessionStore())
        s.admit_prompt("u", prompt)
        s.decode_window({"u": w1})
        s.evict("u")                       # persist mid-generation
        s.store._warm.pop("u", None)       # force the archive restore path
        s.admit_prompt("rival", _prompt("rival", 5, vocab))  # takes slot 0
        s.step()                           # pool advances while u is parked
        slot = s.admit_prompt("u", prompt)  # restored; prompt ignored
        assert slot != s.user_slot["rival"]
        assert s.pending("u") == w2[0]
        out = s.decode_window({
            "u": w2,
            "rival": np.full((k,), s.pending("rival"), np.int32)})
        np.testing.assert_array_equal(np.asarray(out["u"]), ref_logits)
        _assert_trees_equal(ref_sess, _np(s.session_view("u")))


class TestCompileAudit:
    @pytest.mark.parametrize("impl,datapath", DIAG)
    def test_pinned_program_counts(self, impl, datapath):
        """The full per-entry-point executable dict after a canonical serve
        sequence, pinned exactly: any helper that silently becomes its own
        jitted program (or any shape leak that splits an existing one)
        changes a number here."""
        model, params = _model("dense", impl, datapath)
        vocab = model.cfg.vocab
        s = LMScheduler(model, params, slots=3, max_len=24)
        # untraced audit: every program registered before first use; only
        # slot_take has compiled (the session factory gathers slot 0 of
        # the initial pool to build the fresh-session template)
        assert s.compiled_programs() == {
            "slot_put": 0, "slot_take": 1, "recorder_reset": 0,
            "prefill": 0, "decode_step": 0,
            "decode_window": 0, "decode_step_telemetry": 0,
            "decode_window_telemetry": 0,
            "decode_step_record": 0, "decode_window_record": 0}

        s.admit_prompt("a", _prompt("a", 6, vocab))
        s.admit_prompt("b", _prompt("b", 4, vocab))   # 2nd prompt LENGTH
        for _ in range(2):
            s.step()                                  # cached after 1st
        s.step(telemetry=True)
        k2 = {u: np.full((2,), s.pending(u), np.int32) for u in ("a", "b")}
        s.decode_window(k2)
        s.decode_window(k2, telemetry=True)
        s.evict("b")
        expected = {
            "slot_put": 1, "slot_take": 1,
            "recorder_reset": 0,          # health not enabled: never traced
            "prefill": 2,                 # one per distinct prompt length
            "decode_step": 1, "decode_step_telemetry": 1,
            "decode_window": 1, "decode_window_telemetry": 1,
            "decode_step_record": 0, "decode_window_record": 0,
        }
        assert s.compiled_programs() == expected
        assert s.compile_count() == sum(expected.values())

        # a NEW window length is the one legitimate growth: exactly the
        # windowed entry point gains one executable, nothing else moves
        s.decode_window({"a": np.full((3,), s.pending("a"), np.int32)})
        assert s.compiled_programs() == dict(expected, decode_window=2)


class TestServeAdapterPool:
    """launch/serve.py's scheduler-admit path (the old per-row slot_put
    loop): AdapterPool sessions persist and resume bit-exactly."""

    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_durable_roundtrip_and_resume(self, datapath, tmp_path):
        from repro.launch.serve import generate

        cfg = factory.build("qwen3-4b", smoke=True).cfg.with_(
            plastic_adapter=True, adapter_neurons=8, adapter_impl="xla",
            adapter_quant=(datapath == "int8"))
        model = factory.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        params["adapter"]["scale"] = jnp.float32(0.5)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                     cfg.vocab)
        users = ["user0", "user1"]

        store = SessionStore(root=str(tmp_path), capacity=2)
        pool = AdapterPool(cfg, slots=2, store=store)
        for u in users:
            pool.admit(u)
        generate(cfg, params, prompts, max_len=12, gen=3, adapters=pool)
        learned = [_np(pool._take(pool.pool, jnp.int32(s))) for s in (0, 1)]
        assert [int(pool._steps[s]) for s in (0, 1)] == [3, 3]
        for u in users:
            pool.evict(u)

        # "second run": fresh store over the same directory, fresh pool —
        # admission must restore every user's learned rows bit-exactly
        store2 = SessionStore(root=str(tmp_path), capacity=2)
        pool2 = AdapterPool(cfg, slots=2, store=store2)
        for u in users:
            pool2.admit(u)
        assert store2.restores == 2 and store2.creates == 0
        for s in (0, 1):
            _assert_trees_equal(learned[s],
                                _np(pool2._take(pool2.pool, jnp.int32(s))))
            assert int(pool2._steps[s]) == 3

        # resumed sessions keep learning: counters accumulate and the
        # learned rows move on from (not back to) the restored state
        generate(cfg, params, prompts, max_len=12, gen=2, adapters=pool2)
        assert [int(pool2._steps[s]) for s in (0, 1)] == [5, 5]
        resumed = [_np(pool2._take(pool2.pool, jnp.int32(s)))
                   for s in (0, 1)]
        changed = any(
            not np.array_equal(x, y)
            for s in (0, 1)
            for x, y in zip(jax.tree.leaves(learned[s]),
                            jax.tree.leaves(resumed[s])))
        assert changed, "resumed sessions did not learn"
