"""Two-phase learning framework (FireFly-P Sec. II-B).

Phase 1 (offline): PEPG searches plasticity-coefficient space; each candidate
theta is scored by rolling out a plastic SNN — weights start at ZERO and are
rewritten online by the rule — across the training tasks.  The learned object
is the *rule*, never the weights.

Phase 2 (online): theta* frozen; the controller adapts its synapses on the
fly, including under perturbations (actuator failure) and on unseen tasks.

A weight-trained baseline (ES directly over synaptic weights, plasticity off)
reproduces the paper's Fig. 3 comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import es, snn
from repro.envs.base import Env


@dataclasses.dataclass(frozen=True)
class AdaptationConfig:
    hidden: int = 128                  # paper: 128 hidden neurons for control
    timesteps: int = 4
    trace_decay: float = 0.8
    pop_pairs: int = 24
    generations: int = 60
    episodes_per_task: int = 1
    theta_scale: float = 0.05          # PEPG sigma_init over theta space
    seed: int = 0
    impl: str = "xla"                  # PlasticEngine backend for rollouts


def make_snn_config(env: Env, cfg: AdaptationConfig, plastic: bool = True) -> snn.SNNConfig:
    return snn.SNNConfig(
        layer_sizes=(env.obs_dim, cfg.hidden, env.act_dim),
        timesteps=cfg.timesteps, trace_decay=cfg.trace_decay,
        plastic=plastic, impl=cfg.impl)


def episode_return(env: Env, scfg: snn.SNNConfig, theta_or_w: jax.Array,
                   task: jax.Array, key: jax.Array,
                   actuator_mask: Optional[jax.Array] = None,
                   mask_after: Optional[int] = None) -> jax.Array:
    """Roll one episode; returns cumulative reward.

    For plastic nets `theta_or_w` is the flat plasticity-coefficient vector
    and synaptic weights start at zero (Phase-2 semantics).  For the
    weight-trained baseline it is the flat weight vector, frozen.

    `mask_after`: env step after which `actuator_mask` kicks in (simulated
    mid-episode leg failure); None applies the mask from t=0.
    """
    k_env, k_enc = jax.random.split(key)
    state = snn.init_state(scfg)
    if scfg.plastic:
        theta = snn.unflatten_theta(scfg, theta_or_w)
    else:
        theta = snn.init_theta(scfg, jax.random.PRNGKey(0), scale=0.0)
        state = dataclasses.replace(
            state, w=tuple(unflatten_weights(scfg, theta_or_w)))

    est = env.reset(k_env, task)
    full_mask = jnp.ones((env.act_dim,))
    fail_mask = full_mask if actuator_mask is None else actuator_mask

    def step(carry, t):
        est, st = carry
        mask = fail_mask if mask_after is None else jnp.where(
            t >= mask_after, fail_mask, full_mask)
        est = est._replace(actuator_mask=mask)
        obs = env.observe(est)
        st, action = snn.controller_step(scfg, st, theta, obs, k_enc)
        est, r = env.step(est, action)
        return (est, st), r

    (_, _), rewards = jax.lax.scan(step, (est, state), jnp.arange(env.episode_len))
    return rewards.sum()


def unflatten_weights(scfg: snn.SNNConfig, flat: jax.Array):
    out, off = [], 0
    for i in range(scfg.num_layers):
        shape = (scfg.layer_sizes[i], scfg.layer_sizes[i + 1])
        n = shape[0] * shape[1]
        out.append(flat[off:off + n].reshape(shape).astype(scfg.dtype))
        off += n
    return out


def weight_size(scfg: snn.SNNConfig) -> int:
    return sum(scfg.layer_sizes[i] * scfg.layer_sizes[i + 1]
               for i in range(scfg.num_layers))


def make_fitness_fn(env: Env, scfg: snn.SNNConfig, tasks: jax.Array,
                    crn: bool = False):
    """Mean return across training tasks, vmapped over the ES population.

    Each candidate gets its OWN PRNG key (independent env resets / encoding
    noise).  The historical behaviour — broadcasting ONE key so the whole
    population shares identical episode randomness — was an accident; it is
    now the explicit ``crn=True`` option (common random numbers, a variance-
    reduction choice that couples every candidate's evaluation noise).
    """

    def single(param_vec: jax.Array, key: jax.Array) -> jax.Array:
        keys = jax.random.split(key, tasks.shape[0])
        rets = jax.vmap(
            lambda task, k: episode_return(env, scfg, param_vec, task, k)
        )(tasks, keys)
        return rets.mean()

    def fitness(pop: jax.Array, key: jax.Array) -> jax.Array:
        if crn:
            keys = jnp.broadcast_to(key, (pop.shape[0], *key.shape))
        else:
            keys = jax.random.split(key, pop.shape[0])
        return jax.vmap(single)(pop, keys)

    return fitness


def optimize_rule(env: Env, cfg: AdaptationConfig,
                  plastic: bool = True) -> tuple[jax.Array, jax.Array, snn.SNNConfig]:
    """Phase 1.  Returns (theta*_flat or w*_flat, fitness history, snn cfg)."""
    scfg = make_snn_config(env, cfg, plastic=plastic)
    n = snn.theta_size(scfg) if plastic else weight_size(scfg)
    pcfg = es.PEPGConfig(num_params=n, pop_pairs=cfg.pop_pairs,
                         sigma_init=cfg.theta_scale)
    fitness = make_fitness_fn(env, scfg, env.train_tasks())
    key = jax.random.PRNGKey(cfg.seed)
    state, history = es.run(pcfg, fitness, key, cfg.generations)
    return state.mu, history, scfg


def evaluate_generalization(env: Env, scfg: snn.SNNConfig, params: jax.Array,
                            seed: int = 1,
                            actuator_mask: Optional[jax.Array] = None,
                            mask_after: Optional[int] = None) -> jax.Array:
    """Phase 2 on the 72 unseen tasks.  Returns per-task returns.

    Routed through the scenario engine's closed-loop fleet harness: all 72
    eval tasks run as one fused B=72 rollout through `engine.layer_step`'s
    fleet path (per-slot weights), with the actuator-failure stress
    expressed as an `ActuatorDropout` perturbation schedule — the same
    program `benchmarks/robustness.py` sweeps.
    """
    from repro.scenarios import harness as H
    from repro.scenarios import perturb as P

    tasks = env.eval_tasks()
    b = tasks.shape[0]
    prog = H.make_closed_loop(env, scfg, batch=b, steps=env.episode_len)
    if scfg.plastic:
        theta, w0 = params, None
    else:
        theta = snn.flatten_theta(
            snn.init_theta(scfg, jax.random.PRNGKey(0), scale=0.0))
        w0 = unflatten_weights(scfg, params)
    schedule = None
    if actuator_mask is not None:
        pert = P.ActuatorDropout(
            step=0 if mask_after is None else int(mask_after),
            mask=tuple(float(m) for m in jnp.asarray(actuator_mask)))
        schedule = P.compile_schedule(env, (pert,), jax.random.PRNGKey(seed),
                                      b)
    res = prog.run(theta, jax.random.PRNGKey(seed), tasks=tasks,
                   schedule=schedule, w0=w0)
    return res.rewards.sum(axis=0)
