"""Jit'd public wrapper for the fused dual-engine step.

`impl` selects: "pallas" (TPU target; `interpret=True` for CPU validation)
or "xla" (the ref oracle — what the dry-run and CPU benchmarks lower).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import ref as _ref


@functools.partial(
    jax.jit,
    static_argnames=("tau_m", "v_th", "v_reset", "trace_decay", "w_clip",
                     "plastic", "impl", "interpret", "block_m"))
def dual_engine_step(x, w, theta, v, trace_pre, trace_post, *,
                     tau_m: float = 2.0, v_th: float = 1.0,
                     v_reset: float = 0.0, trace_decay: float = 0.8,
                     w_clip: float = 4.0, plastic: bool = True,
                     impl: str = "xla", interpret: bool = False,
                     block_m: int = 128):
    kw = dict(tau_m=tau_m, v_th=v_th, v_reset=v_reset,
              trace_decay=trace_decay, w_clip=w_clip, plastic=plastic)
    if impl == "pallas":
        return _kernel.dual_engine_step_pallas(
            x, w, theta, v, trace_pre, trace_post,
            block_m=block_m, interpret=interpret, **kw)
    return _ref.dual_engine_step(x, w, theta, v, trace_pre, trace_post, **kw)
